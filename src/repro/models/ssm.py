"""Mamba-1 selective SSM block (falcon-mamba / jamba mixer).

Channel dimension (d_inner) is sharded over the party ("model") axis — the
recurrent state is per-channel, so the scan needs *no* cross-party
communication (noted in DESIGN §Arch-applicability).  The sequential scan
here is the jnp oracle; the TPU hot path is `repro.kernels.selective_scan`
(Pallas, sequence-blocked with VMEM-carried state).

Layout follows mamba-1: in-proj → (x, z); depthwise causal conv (d_conv=4)
on x; data-dependent Δ, B, C; diagonal A; selective scan
    h_t = exp(Δ_t A) ⊙ h_{t-1} + Δ_t B_t x_t ;  y_t = C_t·h_t + D x_t
output = (y ⊙ silu(z)) @ W_out.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import normal_init, silu


def init_ssm(key, d_model: int, d_state: int = 16, d_conv: int = 4,
             expand: int = 2):
    d_inner = expand * d_model
    dt_rank = max(1, math.ceil(d_model / 16))
    ks = jax.random.split(key, 8)
    a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None, :],
                 (d_inner, 1))
    return {
        "w_in": normal_init(ks[0], (d_model, 2 * d_inner)),
        "conv_w": normal_init(ks[1], (d_conv, d_inner), scale=0.5),
        "conv_b": jnp.zeros((d_inner,), jnp.float32),
        "w_x_dbc": normal_init(ks[2], (d_inner, dt_rank + 2 * d_state)),
        "w_dt": normal_init(ks[3], (dt_rank, d_inner)),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(
                ks[4], (d_inner,),
                minval=math.log(1e-3), maxval=math.log(1e-1))), 1e-4, None))),
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "w_out": normal_init(ks[5], (d_inner, d_model)),
    }


def _causal_conv(x, conv_w, conv_b, state: Optional[jax.Array] = None):
    """Depthwise causal conv over sequence.  x: (B, S, C); conv_w: (K, C).

    ``state``: (B, K-1, C) trailing context from previous tokens (decode).
    Returns (y, new_state).
    """
    k = conv_w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * conv_w[i].astype(x.dtype)
            for i in range(k))
    y = y + conv_b.astype(x.dtype)
    new_state = xp[:, -(k - 1):]
    return y, new_state


def _dbc(params, xa):
    """Data-dependent Δ (B,S,Ci), B/C (B,S,N) from activated conv output."""
    d_state = params["a_log"].shape[1]
    dt_rank = params["w_x_dbc"].shape[1] - 2 * d_state
    dbc = xa @ params["w_x_dbc"].astype(xa.dtype)
    dt_low, b_ssm, c_ssm = jnp.split(dbc, [dt_rank, dt_rank + d_state],
                                     axis=-1)
    dt = jax.nn.softplus(
        (dt_low @ params["w_dt"].astype(xa.dtype)).astype(jnp.float32)
        + params["dt_bias"])
    return dt, b_ssm.astype(jnp.float32), c_ssm.astype(jnp.float32)


def selective_scan_ref(xa, dt, b_ssm, c_ssm, a_log, d_skip,
                       h0: Optional[jax.Array] = None):
    """Sequential oracle.  xa: (B,S,Ci); dt: (B,S,Ci); b/c: (B,S,N).

    Returns (y (B,S,Ci), h_final (B,Ci,N)).
    """
    a = -jnp.exp(a_log)                                  # (Ci, N)
    bsz, s, ci = xa.shape
    n = a.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bsz, ci, n), jnp.float32)

    def step(h, inp):
        xa_t, dt_t, b_t, c_t = inp                        # (B,Ci),(B,Ci),(B,N)
        da = jnp.exp(dt_t[..., None] * a[None])           # (B,Ci,N)
        h = da * h + (dt_t * xa_t.astype(jnp.float32))[..., None] \
            * b_t[:, None, :]
        y = jnp.einsum("bcn,bn->bc", h, c_t)
        return h, y

    xs = (jnp.moveaxis(xa, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(b_ssm, 1, 0), jnp.moveaxis(c_ssm, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + d_skip * xa.astype(jnp.float32)
    return y.astype(xa.dtype), h


def apply_ssm(params, x, *, scan_impl: str = "reference"):
    """Full mamba block, training/prefill.  x: (B, S, D)."""
    d_inner = params["a_log"].shape[0]
    xz = x @ params["w_in"].astype(x.dtype)
    xc, z = jnp.split(xz, [d_inner], axis=-1)
    xc, _ = _causal_conv(xc, params["conv_w"], params["conv_b"])
    xa = silu(xc)
    dt, b_ssm, c_ssm = _dbc(params, xa)
    if scan_impl == "pallas":
        from repro.kernels import selective_scan as ssk
        y, _ = ssk.selective_scan(xa, dt, b_ssm, c_ssm, params["a_log"],
                                  params["d_skip"])
    else:
        y, _ = selective_scan_ref(xa, dt, b_ssm, c_ssm, params["a_log"],
                                  params["d_skip"])
    out = (y * silu(z)) @ params["w_out"].astype(x.dtype)
    return out


def init_ssm_cache(batch: int, d_model: int, d_state: int = 16,
                   d_conv: int = 4, expand: int = 2):
    d_inner = expand * d_model
    return {
        "conv": jnp.zeros((batch, d_conv - 1, d_inner), jnp.bfloat16),
        "h": jnp.zeros((batch, d_inner, d_state), jnp.float32),
    }


def apply_ssm_decode(params, x, cache):
    """One-token step.  x: (B, D) -> (B, D), new cache."""
    d_inner = params["a_log"].shape[0]
    xz = x @ params["w_in"].astype(x.dtype)
    xc, z = jnp.split(xz, [d_inner], axis=-1)
    xc3, new_conv = _causal_conv(xc[:, None], params["conv_w"],
                                 params["conv_b"], state=cache["conv"])
    xa = silu(xc3)[:, 0]                                   # (B, Ci)
    dt, b_ssm, c_ssm = _dbc(params, xa[:, None])
    dt, b_ssm, c_ssm = dt[:, 0], b_ssm[:, 0], c_ssm[:, 0]
    a = -jnp.exp(params["a_log"])
    da = jnp.exp(dt[..., None] * a[None])
    h = da * cache["h"] + (dt * xa.astype(jnp.float32))[..., None] \
        * b_ssm[:, None, :]
    y = jnp.einsum("bcn,bn->bc", h, c_ssm) \
        + params["d_skip"] * xa.astype(jnp.float32)
    out = (y.astype(x.dtype) * silu(z[:, 0] if z.ndim == 3 else z)) \
        @ params["w_out"].astype(x.dtype)
    return out, {"conv": new_conv, "h": h}

"""Mixture-of-Experts with expert-parallel shard_map dispatch.

Expert parallelism over the party ("model") mesh axis, as an explicit
``shard_map`` island (GSPMD left to its own devices lowers the global
scatter catastrophically — measured in EXPERIMENTS §Perf):

* activations arrive replicated over the party axis (they already are
  between layers);
* shard ℓ owns experts [ℓ·E/q, (ℓ+1)·E/q): it dispatches *its own experts'*
  assignments from the local token pool into (E_loc, C, D) capacity buckets
  (sort-based positions, GShard-style overflow drop), runs the per-expert
  SwiGLU einsum, and scatters results back to token order;
* partial outputs are summed with ``psum`` over the party axis — the same
  partial-aggregation pattern as the paper's Algorithm 1 (each party
  contributes the part of the representation its private block produces).

Aux losses (switch load-balance + router z-loss) are computed from the
replicated router logits.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from repro.sharding.api import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.layers import normal_init, silu


def init_moe(key, d_model: int, d_expert: int, n_experts: int):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": normal_init(k1, (d_model, n_experts)),
        "w_gate": normal_init(k2, (n_experts, d_model, d_expert)),
        "w_up": normal_init(k3, (n_experts, d_model, d_expert)),
        "w_down": normal_init(k4, (n_experts, d_expert, d_model)),
    }


def _build_buckets(xt, sel, e_lo, e_loc, cap):
    """Sort-based capacity dispatch for experts [e_lo, e_lo+e_loc).

    xt: (T, D); sel: (T, k).  Returns (buf (E_loc, C, D), meta)."""
    t, d = xt.shape
    top_k = sel.shape[1]
    flat_e = sel.reshape(-1)
    local = flat_e - e_lo
    is_local = (local >= 0) & (local < e_loc)
    # sort assignments by (local) expert; non-local ones sort to the end
    sort_key = jnp.where(is_local, local, e_loc)
    order = jnp.argsort(sort_key, stable=True)
    sorted_e = sort_key[order]
    tok_of = order // top_k
    counts = jnp.bincount(sorted_e, length=e_loc + 1)[:e_loc]
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * top_k) - starts[jnp.clip(sorted_e, 0, e_loc - 1)]
    keep = (sorted_e < e_loc) & (pos >= 0) & (pos < cap)
    pos_c = jnp.clip(pos, 0, cap - 1)
    e_c = jnp.clip(sorted_e, 0, e_loc - 1)

    src = jnp.where(keep[:, None], xt[tok_of], 0.0).astype(xt.dtype)
    buf = jnp.zeros((e_loc, cap, d), xt.dtype).at[e_c, pos_c].add(src)
    meta = (e_c, pos_c, keep, order, is_local)
    return buf, meta


def _expert_ffn(buf, w_gate, w_up, w_down):
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(buf.dtype))
    return jnp.einsum("ecf,efd->ecd", silu(g) * u,
                      w_down.astype(buf.dtype))             # (E_loc, C, D)


def _combine_buckets(y, meta, gate_vals, t, top_k, e_lo, e_loc, cap):
    e_c, pos_c, keep, order, is_local = meta
    d = y.shape[-1]
    y_assign = y[e_c, pos_c]
    y_assign = jnp.where(keep[:, None], y_assign, 0.0)
    inv = jnp.argsort(order, stable=True)
    y_flat = y_assign[inv].reshape(t, top_k, d)
    gates = jnp.where(is_local.reshape(t, top_k), gate_vals, 0.0)
    return jnp.einsum("tkd,tk->td", y_flat.astype(jnp.float32),
                      gates).astype(y.dtype)


def _dispatch_local(xt, sel, gate_vals, e_lo, e_loc, cap, w_gate, w_up,
                    w_down):
    """Dispatch/compute/combine for experts [e_lo, e_lo+e_loc) only."""
    t = xt.shape[0]
    top_k = sel.shape[1]
    buf, meta = _build_buckets(xt, sel, e_lo, e_loc, cap)
    y = _expert_ffn(buf, w_gate, w_up, w_down)
    return _combine_buckets(y, meta, gate_vals, t, top_k, e_lo, e_loc, cap)


def _route(router, xt, top_k: int):
    logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, sel = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True),
                                        1e-9)
    e = router.shape[1]
    density = jnp.mean(jax.nn.one_hot(sel[:, 0], e), axis=0)
    density_prob = jnp.mean(probs, axis=0)
    lb_loss = e * jnp.sum(density * density_prob)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return sel, gate_vals, {"lb_loss": lb_loss, "z_loss": z_loss}


def apply_moe(params, x, *, top_k: int, capacity_factor: float = 1.25
              ) -> Tuple[jax.Array, dict]:
    """Single-shard reference (oracle for tests; also the q=1 path)."""
    b, s, d = x.shape
    e = params["router"].shape[1]
    t = b * s
    xt = x.reshape(t, d)
    sel, gate_vals, aux = _route(params["router"], xt, top_k)
    cap = max(8, min(int(capacity_factor * top_k * t / e), t))
    out = _dispatch_local(xt, sel, gate_vals, 0, e, cap, params["w_gate"],
                          params["w_up"], params["w_down"])
    return out.reshape(b, s, d), aux


def apply_moe_sharded(rt, params, x, *, top_k: int,
                      capacity_factor: float = 1.25,
                      dispatch: str | None = None) -> Tuple[jax.Array, dict]:
    """Expert-parallel shard_map island (see module docstring).

    ``dispatch``:
      * "replicated" (baseline) — every shard routes the full local token
        pool and computes its own experts; outputs psum-combined.
      * "alltoall" (§Perf hillclimb) — each shard routes 1/q of the tokens,
        capacity buckets move to their expert shard with ``all_to_all``
        (and back); only the final token-slice exchange is a psum.  Router
        FLOPs and dispatch traffic drop ~q× / ~k-vs-q×.
    """
    b, s, d = x.shape
    e = params["router"].shape[1]
    bs = rt.bspec(b)
    axis = rt.model_axis
    q = rt.model_size
    e_loc = e // q
    assert e % q == 0, (e, q)
    dispatch = dispatch or getattr(rt, "moe_dispatch", "replicated")

    def island(router, w_gate, w_up, w_down, x_l):
        b_l = x_l.shape[0]
        t = b_l * s
        xt = x_l.reshape(t, d)
        idx = jax.lax.axis_index(axis)
        if dispatch == "alltoall" and t % q == 0 and q > 1:
            t_q = t // q
            xq = jax.lax.dynamic_slice_in_dim(xt, idx * t_q, t_q)
            sel, gate_vals, aux = _route(router, xq, top_k)
            cap = max(8, min(int(capacity_factor * top_k * t_q / e), t_q))
            # build buckets for ALL experts from this shard's token slice
            buf, meta = _build_buckets(xq, sel, 0, e, cap)
            # (E, C, D) -> (E_loc, q·C, D): buckets travel to expert shards
            buf = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=1,
                                     tiled=True)
            y = _expert_ffn(buf, w_gate, w_up, w_down)
            # return trip: (E_loc, q·C, D) -> (E, C, D) per source shard
            y = jax.lax.all_to_all(y, axis, split_axis=1, concat_axis=0,
                                   tiled=True)
            out_q = _combine_buckets(y, meta, gate_vals, t_q, top_k, 0, e,
                                     cap)
            # reassemble the full token pool (replicated over parties)
            pad = jnp.zeros((t, d), out_q.dtype)
            out = jax.lax.psum(
                jax.lax.dynamic_update_slice_in_dim(pad, out_q, idx * t_q,
                                                    0), axis)
        else:
            sel, gate_vals, aux = _route(router, xt, top_k)
            cap = max(8, min(int(capacity_factor * top_k * t / e), t))
            e_lo = idx * e_loc
            out = _dispatch_local(xt, sel, gate_vals, e_lo, e_loc, cap,
                                  w_gate, w_up, w_down)
            out = jax.lax.psum(out, axis)    # combine party contributions
        if bs is not None:                   # global-batch mean of aux losses
            aux = jax.tree.map(lambda a: jax.lax.pmean(a, bs), aux)
        return out.reshape(b_l, s, d), aux

    fn = shard_map(
        island, mesh=rt.mesh,
        in_specs=(P(None, None), P(axis, None, None), P(axis, None, None),
                  P(axis, None, None), P(bs, None, None)),
        out_specs=(P(bs, None, None),
                   {"lb_loss": P(), "z_loss": P()}),
        check_vma=False)
    out, aux = fn(params["router"], params["w_gate"], params["w_up"],
                  params["w_down"], x)
    # aux scalars are identical across shards; take them as-is
    return out, aux

"""Shared building blocks: norms, rotary embeddings, initializers.

Pure-JAX (no flax): params are plain dict pytrees; every module is a pair
(init_fn, apply_fn).  Compute dtype is bf16 with f32 params and f32
softmax/norm accumulation (TPU mixed-precision convention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

ACT_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32


def normal_init(key, shape, scale=0.02, dtype=PARAM_DTYPE):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def rms_norm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, d_head, 2) / d_head))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, dh); positions: (..., S) int32."""
    d_head = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d_head, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x.astype(jnp.float32)).astype(x.dtype)


def gelu(x):
    return jax.nn.gelu(x.astype(jnp.float32)).astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = silu(x @ w_gate.astype(x.dtype))
    u = x @ w_up.astype(x.dtype)
    return (g * u) @ w_down.astype(x.dtype)


def init_mlp(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": normal_init(k1, (d_model, d_ff)),
        "w_up": normal_init(k2, (d_model, d_ff)),
        "w_down": normal_init(k3, (d_ff, d_model)),
    }


def apply_mlp(params, x):
    return swiglu(x, params["w_gate"], params["w_up"], params["w_down"])

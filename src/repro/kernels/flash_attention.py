"""Pallas TPU flash attention (causal / sliding-window / GQA).

TPU-native adaptation of the attention hot spot (DESIGN §6): online-softmax
attention with q/k tiles staged HBM→VMEM by ``pl.pallas_call`` BlockSpecs,
MXU-aligned (128×128) tiles, f32 accumulators in VMEM scratch.  GQA is
expressed in the k/v ``index_map`` (q-head h reads kv-head h//rep), so
grouped K/V are never materialized per q-head.

Layout: q (B, H, Sq, dh); k/v (B, Hkv, Skv, dh); grid (B, H, nQ, nK) with
the kv dimension iterated minor-most (sequentially on TPU) so the (m, l,
acc) scratch carries across kv tiles of one q tile.

Validated in ``interpret=True`` mode against ``ref.attention_ref`` (this
container is CPU-only; TPU is the compile target).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  window: int | None, seq_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (BQ, dh)
    k = k_ref[0, 0].astype(jnp.float32)                  # (BK, dh)
    s = q @ k.T                                          # (BQ, BK)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = kpos < seq_kv
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] \
        + p @ v_ref[0, 0].astype(jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: (B, H, Sq, dh); k/v: (B, Hkv, Skv, dh) -> (B, H, Sq, dh)."""
    b, h, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert h % hkv == 0
    rep = h // hkv
    scale = dh ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(skv, block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, seq_kv=skv)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b_, h_, qi, ki, rep=rep: (b_, h_ // rep, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b_, h_, qi, ki, rep=rep: (b_, h_ // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max m
            pltpu.VMEM((block_q,), jnp.float32),      # running sum-exp l
            pltpu.VMEM((block_q, dh), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)

"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) so the kernel body
executes in Python for correctness validation; on a real TPU backend pass
``interpret=False`` (or rely on the default platform detection) to compile
through Mosaic.

``vfl_grad`` is the batched rank-k fused forward/backward VFL kernel; both
of its reductions (z across feature tiles, g across batch tiles) complete
*inside* the kernel, so these wrappers perform no out-of-kernel math.  A
side whose reduction fits a single grid visit (one feature tile for z,
one backward row tile for g) elides its VMEM accumulator entirely and
writes the output directly — the common case for the deep-VFL encoder
layers' narrow contractions.  The canonical consumer is the fused
federated step engine (`repro.core.engine`), which runs whole VFB² epochs
(linear and deep) as one compiled program and routes its X-block
contractions here on TPU backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import selective_scan as _ss
from repro.kernels import vfl_grad as _vg


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, block_q=128,
                    block_k=128, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "block_c", "interpret"))
def selective_scan(xa, dt, b_ssm, c_ssm, a_log, d_skip, *, chunk=128,
                   block_c=512, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    y, _ = _ss.selective_scan(xa, dt, b_ssm, c_ssm, a_log, d_skip,
                              chunk=chunk, block_c=block_c,
                              interpret=interpret)
    return y


@functools.partial(jax.jit, static_argnames=("block_b", "block_d",
                                             "interpret", "mode", "denom",
                                             "split"))
def _vfl_grad_jit(xb, w, theta, lam, *, block_b, block_d, interpret, mode,
                  denom, split):
    return _vg.vfl_grad(xb, w, theta, lam, block_b=block_b, block_d=block_d,
                        interpret=interpret, mode=mode, denom=denom,
                        split=split)


@functools.partial(jax.jit, static_argnames=("block_b", "block_d",
                                             "interpret", "mode", "denom",
                                             "split"))
def _vfl_grad_jit_no_w(xb, theta, *, block_b, block_d, interpret, mode,
                       denom, split):
    # w=None requires a concrete lam=0 (no λw term exists), so the no-w
    # path keeps λ out of the traced signature entirely.
    return _vg.vfl_grad(xb, None, theta, 0.0, block_b=block_b,
                        block_d=block_d, interpret=interpret, mode=mode,
                        denom=denom, split=split)


@functools.partial(jax.jit, static_argnames=("block_b", "block_d",
                                             "interpret", "mode", "denom",
                                             "split"))
def _vfl_grad_jit_lam0(xb, w, theta, *, block_b, block_d, interpret, mode,
                       denom, split):
    # Concrete λ=0 skips the λw term (and its SMEM operand) at trace time;
    # this is also the only legal path when the split-batch sides carry
    # different column counts (λw is then undefined).
    return _vg.vfl_grad(xb, w, theta, 0.0, block_b=block_b,
                        block_d=block_d, interpret=interpret, mode=mode,
                        denom=denom, split=split)


def vfl_grad(xb, w, theta, lam=0.0, *, block_b=128, block_d=128,
             interpret=None, mode="fused", denom=None, split=None):
    """Batched rank-k fused VFL kernel: z = xb@w, g = xbᵀθ/denom + λw.

    ``w``/``theta`` may carry a trailing M axis (M concurrent iterates /
    ϑ vectors — multi-dominator or variance-reduced batching); non-tile
    shapes are padded internally.  Both outputs arrive fully reduced from
    the kernel.  ``mode="backward"`` additionally accepts ``w=None`` (with
    ``lam=0``): the pure-XᵀΘ BUM application streams no weight operand —
    the engine's multi-dominator epochs route their M = m per-dominator
    backward through this.

    ``lam`` is a **traced operand** of the jitted wrapper — sweeping the
    regularizer (hyperparameter search, per-epoch schedules) reuses one
    compilation instead of recompiling per value.

    ``split`` activates the split-batch fused form (pipelined epochs):
    rows [0, split) are the backward block (ϑ rows), rows [split, B) the
    forward block (returned z rows); see ``repro.kernels.vfl_grad``.
    """
    if interpret is None:
        interpret = _default_interpret()
    if w is None:
        if not _vg._concrete_zero(lam):
            raise ValueError("w=None requires a concrete lam=0 "
                             "(no λw term exists without w)")
        return _vfl_grad_jit_no_w(xb, theta, block_b=block_b,
                                  block_d=block_d, interpret=interpret,
                                  mode=mode, denom=denom, split=split)
    if _vg._concrete_zero(lam):
        return _vfl_grad_jit_lam0(xb, w, theta, block_b=block_b,
                                  block_d=block_d, interpret=interpret,
                                  mode=mode, denom=denom, split=split)
    return _vfl_grad_jit(xb, w, theta, jnp.asarray(lam, jnp.float32),
                         block_b=block_b, block_d=block_d,
                         interpret=interpret, mode=mode, denom=denom,
                         split=split)


@functools.partial(jax.jit, static_argnames=("window", "block_k",
                                             "interpret"))
def decode_attention(q, k_cache, v_cache, pos, shard_offset, window=None, *,
                     block_k=256, interpret=None):
    """Flash-decoding partials (o, m, l) — LSE-merge-ready (see
    repro.kernels.decode_attention)."""
    if interpret is None:
        interpret = _default_interpret()
    from repro.kernels import decode_attention as _da
    return _da.decode_attention(q, k_cache, v_cache, pos, shard_offset,
                                window, block_k=block_k,
                                interpret=interpret)

"""Pallas TPU flash-decoding kernel: one-token attention over a local KV
cache shard, emitting (unnormalized output, running max, sum-exp) so the
partial results can be LSE-merged across cache shards with ``psum`` — the
kernel form of ``repro.models.attention.local_decode_attention`` (the
sequence-sharded serve path, §Perf hillclimb 1).

Grid (B, H, nK): kv blocks iterate minor-most (sequentially) with the
(m, l, acc) state carried in VMEM scratch; GQA via the k/v index_map
(head h reads kv head h//rep).  pos/offset/window arrive as tiny s32
arrays (scalar operands), so one compiled kernel serves every decode step
and every shard.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(scalars_ref, q_ref, k_ref, v_ref, o_ref, m_out, l_out,
                   m_ref, l_ref, acc_ref, *, block_k: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos, offset, window = scalars_ref[0], scalars_ref[1], scalars_ref[2]
    q = q_ref[0, 0].astype(jnp.float32)                  # (dh,)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (BK, dh)
    v = v_ref[0, :, 0].astype(jnp.float32)               # (BK, dh)
    dh = q.shape[0]
    s = k @ q * (dh ** -0.5)                             # (BK,)

    kpos = offset + ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (k.shape[0],), 0)
    valid = (kpos <= pos) & (kpos > pos - window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[0] = alpha * l_ref[0] + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + p @ v
    m_ref[0] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)
        m_out[0, 0] = m_ref[0]
        l_out[0, 0] = l_ref[0]


def decode_attention(q, k_cache, v_cache, pos, shard_offset, window=None, *,
                     block_k: int = 256, interpret: bool = True):
    """q: (B, H, dh); caches: (B, S_loc, Hkv, dh); pos/shard_offset: scalar
    i32.  Returns (o (B,H,dh) f32 unnormalized, m (B,H), l (B,H)) — the
    same contract as ``local_decode_attention`` (LSE-merge ready)."""
    b, h, dh = q.shape
    s_loc, hkv = k_cache.shape[1], k_cache.shape[2]
    rep = h // hkv
    block_k = min(block_k, s_loc)
    assert s_loc % block_k == 0
    nk = s_loc // block_k
    win = jnp.asarray(window if window is not None else 1 << 30, jnp.int32)
    scalars = jnp.stack([jnp.asarray(pos, jnp.int32),
                         jnp.asarray(shard_offset, jnp.int32), win])

    kernel = functools.partial(_decode_kernel, block_k=block_k)
    o, m, l = pl.pallas_call(
        kernel,
        grid=(b, h, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),       # scalars
            pl.BlockSpec((1, 1, dh), lambda bi, hi, ki: (bi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda bi, hi, ki, rep=rep: (bi, ki, hi // rep, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda bi, hi, ki, rep=rep: (bi, ki, hi // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, dh), lambda bi, hi, ki: (bi, hi, 0)),
            pl.BlockSpec((1, 1), lambda bi, hi, ki: (bi, hi)),
            pl.BlockSpec((1, 1), lambda bi, hi, ki: (bi, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((dh,), jnp.float32),
        ],
        interpret=interpret,
    )(scalars, q, k_cache, v_cache)
    return o, m, l

"""Pallas TPU fused VFL partial-product + BUM gradient kernel (rank-k).

The paper's per-iteration hot loop on a party is two passes over the same
minibatch feature block: the *forward* partial products
``z_i = w_{G_ℓ}ᵀ(x_i)_{G_ℓ}`` (Algorithm 1 step 2) and — after ϑ returns —
the *backward* rank-k update ``g = X_bᵀϑ/B + λ∇g(w)`` (Algorithm 3 step 3).
On the paper's CPUs this is cache-line bound; the TPU adaptation fuses both
passes so the X block is read from HBM once per invocation, tiled through
VMEM with both MXU contractions done per tile.

Batched rank-k form: one invocation processes **M concurrent iterates /
ϑ vectors** — the multi-dominator case of Algorithms 2/3 (m active parties
each issue a ϑ), and the variance-reduced algorithms (SVRG evaluates the
current iterate and the snapshot, M = 2) — in a *single* HBM pass over X:

    z = X @ W        (B, Mw)   forward partial products, one column per iterate
    g = XᵀΘ/B + λW   (D, Mθ)   BUM gradients, one column per ϑ

Both reductions complete **in-kernel**: z is accumulated across feature
tiles in a full-minibatch VMEM scratch (so callers never re-sum partials on
the host), g across batch tiles in a per-feature-tile scratch.  Inputs may
be bf16; all accumulation is f32 in VMEM.

Grid (nD, nB) — batch tiles minor-most (sequential) so the g accumulator
carries across batch tiles for a fixed feature tile; the z accumulator is a
full (B, M) scratch written through on every visit, so the last feature
pass (di == nD−1) leaves the completed sum in HBM (the grid is sequential:
last write wins).  Either accumulator is **elided** when its reduction
completes in a single visit — nD == 1 for z, a single backward row tile
for g — so narrow operands (the deep-VFL encoder layers, rank-1 single-
tile minibatches) write their outputs straight through with no dead VMEM
scratch and no per-grid-step accumulator traffic in interpret mode.

Shapes that do not divide the tile are zero-padded inside the wrapper and
the outputs sliced back, so odd party widths (``PartyLayout.even`` with
d % q != 0) work without caller-side ceremony.

``mode`` selects which contraction is materialized:
  * "fused"    — both (the async hot loop: ϑ from the previous round is
                 applied while the next round's partials are produced);
  * "forward"  — z only (pre-aggregation, ϑ not yet known);
  * "backward" — g only (post-aggregation BUM application).

Split-batch fused form (the pipelined-epoch hot path): the two sides of a
fused invocation may ride **distinct minibatch row-blocks** concatenated
into one X operand.  ``split=Bb`` declares rows [0, Bb) backward-only
(round t's BUM application) and rows [Bb, B) forward-only (round t+1's
partial products): ϑ is supplied for the backward rows alone (the wrapper
zero-masks the forward rows out of the XᵀΘ contraction, padding-aware) and
z is returned for the forward rows alone.  The column counts of the two
sides are then independent, and both sides may be **vector-valued**:
a single forward iterate next to M = m per-dominator ϑ columns
(block-diagonal Θ, the linear multi-dominator epochs), the deep pipelined
epochs' Mw = hidden encoder layer (W₁) beside Mθ = hidden Jacobian
cotangents (du), or Mθ = m·hidden block-diagonal du slabs in the
multi-dominator deep regime — one kernel grid streams the w/ϑ tiles once
and serves backward(t) ∥ forward(t+1) in a single launch instead of two
(``core.engine`` pipelined scan bodies are jaxpr-audited at exactly one
``pallas_call``).

λ is a **traced scalar operand** (SMEM), not a compile-time constant, so
sweeping the regularizer never recompiles the kernel.  It is required to
be a concrete 0 only where the λW term is undefined (``w=None`` backward,
or split-batch calls whose side column counts differ).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _concrete_zero(lam) -> bool:
    """True iff ``lam`` is a host scalar equal to 0 (tracers are never)."""
    if isinstance(lam, (int, float, np.floating, np.integer)):
        return float(lam) == 0.0
    if isinstance(lam, (jnp.ndarray, np.ndarray)) \
            and not isinstance(lam, jax.core.Tracer):
        return float(lam) == 0.0
    return False


def _vfl_kernel(*refs, denom: int, block_b: int, fwd: bool, bwd: bool,
                has_w: bool, use_lamw: bool, nsplit: int | None,
                z_acc_used: bool, g_acc_used: bool):
    # Single-sided modes carry only their own operands/outputs (no HBM
    # traffic for a dead side); ref order follows the wrapper's specs.
    # ``has_w=False`` (backward with ``w=None``) additionally drops the
    # weight operand — the engine's multi-dominator BUM application only
    # needs XᵀΘ, so no dead (D, M) block is streamed into VMEM.
    # ``nsplit`` (split-batch form) is the number of backward-only row
    # tiles: tiles bi < nsplit skip the forward dot, tiles bi >= nsplit
    # skip the backward accumulate — each side's MXU work runs on its own
    # rows only, so the fused launch does the same flops as two
    # single-sided launches.
    # Scratch elision: a side whose reduction completes within one grid
    # visit (z with a single feature tile, g with a single backward row
    # tile) writes its output ref directly — no VMEM accumulator is
    # allocated and no per-grid-step accumulator traffic happens
    # (``z_acc_used``/``g_acc_used`` gate the scratch refs).
    it = iter(refs)
    x_ref = next(it)
    w_ref = next(it) if has_w else None
    theta_ref = next(it) if bwd else None
    lam_ref = next(it) if use_lamw else None
    z_ref = next(it) if fwd else None
    g_ref = next(it) if bwd else None
    z_acc = next(it) if fwd and z_acc_used else None
    g_acc = next(it) if bwd and g_acc_used else None

    di = pl.program_id(0)
    bi = pl.program_id(1)
    nb = pl.num_programs(1)

    x = x_ref[...].astype(jnp.float32)                    # (Bb, Db)
    w = None if w_ref is None else w_ref[...].astype(jnp.float32)  # (Db, Mw)

    if fwd:
        def _z_work():
            # forward partials for this (feature, batch) tile: rank-k MXU
            zt = jnp.dot(x, w, preferred_element_type=jnp.float32)
            if z_acc is None:
                # nD == 1: one feature pass computes the full z — write
                # the output block directly, no accumulator round-trip
                z_ref[...] = zt
                return
            sl = pl.ds(bi * block_b, block_b)

            @pl.when(di == 0)
            def _z_init():
                z_acc[sl, :] = zt

            @pl.when(di > 0)
            def _z_accum():
                z_acc[sl, :] += zt

            # Written on every visit; the grid is sequential, so the final
            # feature pass (di == nD-1) is the last writer and the HBM
            # block holds the fully reduced z.  No out-of-kernel reduction
            # remains.  (Split-batch: backward-row tiles never write their
            # z block — the wrapper slices those rows away.)
            z_ref[...] = z_acc[sl, :]

        if nsplit is None:
            _z_work()
        else:
            pl.when(bi >= nsplit)(_z_work)

    if bwd and g_acc is None:
        # A single backward row tile: XᵀΘ is complete after one visit, so
        # finalize (scale + λW) inline and skip the accumulator.  The
        # output block for feature tile di persists across the remaining
        # (forward-only) batch-tile visits — same sequential-grid
        # revisiting contract the z path relies on.
        def _g_once():
            th = theta_ref[...].astype(jnp.float32)       # (Bb, Mθ)
            acc = jnp.dot(x.T, th,
                          preferred_element_type=jnp.float32) / denom
            if use_lamw:
                acc = acc + lam_ref[0, 0] * w
            g_ref[...] = acc.astype(g_ref.dtype)

        if nsplit is None:
            _g_once()
        else:
            pl.when(bi < nsplit)(_g_once)
    elif bwd:
        @pl.when(bi == 0)
        def _g_init():
            g_acc[...] = jnp.zeros_like(g_acc)

        def _g_work():
            th = theta_ref[...].astype(jnp.float32)       # (Bb, Mθ)
            # backward accumulate: XᵀΘ, f32 in VMEM
            g_acc[...] += jnp.dot(x.T, th,
                                  preferred_element_type=jnp.float32)

        if nsplit is None:
            _g_work()
        else:
            pl.when(bi < nsplit)(_g_work)

        @pl.when(bi == nb - 1)
        def _g_finalize():
            acc = g_acc[...] / denom
            if use_lamw:
                acc = acc + lam_ref[0, 0] * w
            g_ref[...] = acc.astype(g_ref.dtype)


def vfl_grad(xb, w, theta, lam=0.0, *, block_b: int = 128,
             block_d: int = 128, interpret: bool = True, mode: str = "fused",
             denom: int | None = None, split: int | None = None):
    """xb: (B, D); w: (D,), (D, Mw) or None; theta: (B,), (B, Mθ) or None.

    Returns ``(z, g)`` with z = xb @ w fully reduced in-kernel (shape (B,)
    or (B, Mw)) and g = xbᵀθ/denom + λw (shape (D,) or (D, Mθ)).  ``denom``
    defaults to the number of backward rows (the minibatch gradient 1/B
    scaling); SAGA's running average passes n.  Rank-1 inputs get rank-1
    outputs (per side).  ``lam`` may be a traced scalar — distinct
    regularizer values share one compilation.

    Single-sided modes return ``None`` for the inactive side and carry no
    HBM traffic for it; ``theta=None`` is allowed (and ϑ-free) in
    ``mode="forward"``, and ``w=None`` is allowed in ``mode="backward"``
    when ``lam == 0`` (pure XᵀΘ — the multi-dominator BUM application;
    the dead weight block is then never streamed into VMEM).

    ``split`` (fused mode only) activates the **split-batch** form: xb is
    the concatenation of a backward row-block (rows [0, split)) and a
    forward row-block (rows [split, B)).  ``theta`` then has ``split``
    rows (it is zero-masked over the forward rows before the XᵀΘ pass) and
    the returned z covers only the forward rows.  The two sides' column
    counts Mw/Mθ may differ; the λw term requires Mw == Mθ (pass a
    concrete ``lam=0`` otherwise — the engine adds its regularizer
    outside the kernel).
    """
    b, d = xb.shape
    assert mode in ("fused", "forward", "backward"), mode
    if split is not None:
        assert mode == "fused", "split-batch form is fused-mode only"
        assert 0 < split < b, (split, b)
    if w is None:
        assert mode == "backward", "w=None only valid in mode='backward'"
        if not _concrete_zero(lam):
            raise ValueError("the λw term needs w; pass a concrete lam=0 "
                             "with w=None")
        assert theta is not None
        w2, mw = None, None
        squeeze_z = False
    else:
        squeeze_z = (w.ndim == 1)
        w2 = w[:, None] if w.ndim == 1 else w
        mw = w2.shape[1]
    if theta is None:
        assert mode == "forward", "theta required outside mode='forward'"
        th2, mth = None, None
        squeeze_g = False
    else:
        squeeze_g = (theta.ndim == 1)
        th2 = theta[:, None] if theta.ndim == 1 else theta
        mth = th2.shape[1]
        nrows_bwd = b if split is None else split
        assert th2.shape[0] == nrows_bwd, (th2.shape, nrows_bwd)
        if split is None and mw is not None:
            assert mw == mth, (mw, mth)
    denom = (b if split is None else split) if denom is None else int(denom)

    fwd = mode in ("fused", "forward")
    bwd = mode in ("fused", "backward")
    has_w = w2 is not None
    # λw is only defined when both sides share a column count; the traced
    # operand is skipped entirely for a concrete zero (no dead SMEM read).
    use_lamw = bwd and has_w and mw == mth and not _concrete_zero(lam)
    if bwd and not use_lamw and not _concrete_zero(lam):
        raise ValueError(
            "nonzero lam requires w with matching column counts "
            f"(Mw={mw}, Mθ={mth}); pass a concrete lam=0 and apply the "
            "regularizer outside the kernel")

    # Pad to tile multiples instead of rejecting odd shapes; zero rows/cols
    # contribute zero to both products.  The 128-lane rounding is a Mosaic
    # tiling requirement; interpret mode (off-TPU validation) has no tiling
    # constraint, so it rounds to the 8-sublane granule only and the padded
    # copy volume stops dominating emulated runs.
    lane = 128 if not interpret else 8
    block_d = min(block_d, _round_up(d, lane))
    dp = _round_up(d, block_d)
    if split is None:
        block_b = min(block_b, _round_up(b, 8))
        bp = _round_up(b, block_b)
        nsplit = None
        if bp != b or dp != d:
            xb = jnp.pad(xb, ((0, bp - b), (0, dp - d)))
            if th2 is not None:
                th2 = jnp.pad(th2, ((0, bp - b), (0, 0)))
    else:
        # Per-side row padding so every row tile is purely backward or
        # purely forward — the kernel specializes on the tile index and
        # each side's MXU pass touches only its own rows.
        bf = b - split
        block_b = min(block_b, _round_up(split, 8), _round_up(bf, 8))
        split_p, bf_p = _round_up(split, block_b), _round_up(bf, block_b)
        bp = split_p + bf_p
        nsplit = split_p // block_b
        if split_p != split or bf_p != bf or dp != d:
            xb = jnp.concatenate([
                jnp.pad(xb[:split], ((0, split_p - split), (0, dp - d))),
                jnp.pad(xb[split:], ((0, bf_p - bf), (0, dp - d)))])
        # ϑ rows live on the backward tiles; the forward tiles' (never
        # read) Θ blocks stay zero.
        th2 = jnp.pad(th2, ((0, bp - split), (0, 0)))
    if w2 is not None and dp != d:
        w2 = jnp.pad(w2, ((0, dp - d), (0, 0)))
    nb, nd = bp // block_b, dp // block_d

    # Scratch elision (see kernel): the z accumulator exists only when the
    # forward reduction spans >1 feature tile; the g accumulator only when
    # the backward rows span >1 row tile (all rows without split, the
    # backward block's tiles with it).
    z_acc_used = fwd and nd > 1
    g_acc_used = bwd and (nb if nsplit is None else nsplit) > 1

    kernel = functools.partial(_vfl_kernel, denom=denom, block_b=block_b,
                               fwd=fwd, bwd=bwd, has_w=has_w,
                               use_lamw=use_lamw, nsplit=nsplit,
                               z_acc_used=z_acc_used, g_acc_used=g_acc_used)
    # Mode-specific specs: a single-sided call neither streams the unused
    # operand into VMEM nor DMAs a dead output back to HBM.  A dead side's
    # column count is None, so each side's specs are built only under its
    # own guard.
    in_specs = [pl.BlockSpec((block_b, block_d), lambda di, bi: (bi, di))]
    operands = (xb,)
    if has_w:
        in_specs.append(pl.BlockSpec((block_d, mw), lambda di, bi: (di, 0)))
        operands += (w2,)
    if bwd:
        in_specs.append(pl.BlockSpec((block_b, mth), lambda di, bi: (bi, 0)))
        operands += (th2,)
    if use_lamw:
        in_specs.append(pl.BlockSpec((1, 1), lambda di, bi: (0, 0),
                                     memory_space=pltpu.SMEM))
        operands += (jnp.asarray(lam, jnp.float32).reshape(1, 1),)
    sides = []
    if fwd:
        sides.append((pl.BlockSpec((block_b, mw), lambda di, bi: (bi, 0)),
                      jax.ShapeDtypeStruct((bp, mw), jnp.float32),
                      pltpu.VMEM((bp, mw), jnp.float32) if z_acc_used
                      else None))
    if bwd:
        sides.append((pl.BlockSpec((block_d, mth), lambda di, bi: (di, 0)),
                      jax.ShapeDtypeStruct((dp, mth), jnp.float32),
                      pltpu.VMEM((block_d, mth), jnp.float32) if g_acc_used
                      else None))
    outs = pl.pallas_call(
        kernel,
        grid=(nd, nb),
        in_specs=in_specs,
        out_specs=[s[0] for s in sides],
        out_shape=[s[1] for s in sides],
        scratch_shapes=[s[2] for s in sides if s[2] is not None],
        interpret=interpret,
    )(*operands)
    if not fwd:
        z = None
    elif split is None:
        z = outs[0][:b]
    else:
        z = outs[0][split_p:split_p + (b - split)]   # the forward rows
    g = outs[-1][:d] if bwd else None
    if squeeze_z and z is not None:
        z = z[:, 0]
    if squeeze_g and g is not None:
        g = g[:, 0]
    return z, g

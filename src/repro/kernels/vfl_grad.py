"""Pallas TPU fused VFL partial-product + BUM gradient kernel.

The paper's per-iteration hot loop on a party is two passes over the same
minibatch feature block: the *forward* partial products
``z_i = w_{G_ℓ}ᵀ(x_i)_{G_ℓ}`` (Algorithm 1 step 2) and — after ϑ returns —
the *backward* rank-k update ``g = X_bᵀϑ/B + λ∇g(w)`` (Algorithm 3 step 3).
On the paper's CPUs this is cache-line bound; the TPU adaptation fuses both
passes so the X block is read from HBM once per iteration, tiled
(B_blk × D_blk = 128×128) through VMEM with both MXU contractions done per
tile.

Grid (nD, nB) — batch tiles minor-most (sequential) so the z accumulator
scratch carries across batch tiles for a fixed feature tile; the g output
tile is finalized on the last batch tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _vfl_kernel(x_ref, w_ref, theta_ref, z_ref, g_ref, g_acc, *,
                lam: float, batch: int):
    bi = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(bi == 0)
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)

    x = x_ref[...].astype(jnp.float32)                    # (Bb, Db)
    w = w_ref[...].astype(jnp.float32)                    # (Db,)
    th = theta_ref[...].astype(jnp.float32)               # (Bb,)

    # forward partials for this (batch tile, feature tile): rank-1 MXU pass
    z_ref[0] = (x @ w).astype(z_ref.dtype)                # (Bb,)
    # backward accumulate: Xᵀϑ
    g_acc[...] += x.T @ th

    @pl.when(bi == nb - 1)
    def _finalize():
        g_ref[...] = (g_acc[...] / batch + lam * w).astype(g_ref.dtype)


def vfl_grad(xb, w, theta, lam: float = 0.0, *, block_b: int = 128,
             block_d: int = 128, interpret: bool = True):
    """xb: (B, D); w: (D,); theta: (B,).

    Returns (z_partial (nD, B) per-feature-tile partials, g (D,)).
    ``z_partial.sum(0)`` equals the reference z (the per-tile partials are
    exactly the per-party partial products the protocol masks & aggregates).
    """
    b, d = xb.shape
    block_b = min(block_b, b)
    block_d = min(block_d, d)
    assert b % block_b == 0 and d % block_d == 0
    nb, nd = b // block_b, d // block_d

    kernel = functools.partial(_vfl_kernel, lam=lam, batch=b)
    z_partial, g = pl.pallas_call(
        kernel,
        grid=(nd, nb),
        in_specs=[
            pl.BlockSpec((block_b, block_d), lambda di, bi: (bi, di)),
            pl.BlockSpec((block_d,), lambda di, bi: (di,)),
            pl.BlockSpec((block_b,), lambda di, bi: (bi,)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_b), lambda di, bi: (di, bi)),
            pl.BlockSpec((block_d,), lambda di, bi: (di,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nd, b), jnp.float32),
            jax.ShapeDtypeStruct((d,), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d,), jnp.float32)],
        interpret=interpret,
    )(xb, w, theta)
    return z_partial, g

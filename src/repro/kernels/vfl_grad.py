"""Pallas TPU fused VFL partial-product + BUM gradient kernel (rank-k).

The paper's per-iteration hot loop on a party is two passes over the same
minibatch feature block: the *forward* partial products
``z_i = w_{G_ℓ}ᵀ(x_i)_{G_ℓ}`` (Algorithm 1 step 2) and — after ϑ returns —
the *backward* rank-k update ``g = X_bᵀϑ/B + λ∇g(w)`` (Algorithm 3 step 3).
On the paper's CPUs this is cache-line bound; the TPU adaptation fuses both
passes so the X block is read from HBM once per invocation, tiled through
VMEM with both MXU contractions done per tile.

Batched rank-k form: one invocation processes **M concurrent iterates /
ϑ vectors** — the multi-dominator case of Algorithms 2/3 (m active parties
each issue a ϑ), and the variance-reduced algorithms (SVRG evaluates the
current iterate and the snapshot, M = 2) — in a *single* HBM pass over X:

    z = X @ W        (B, M)   forward partial products, one column per iterate
    g = XᵀΘ/B + λW   (D, M)   BUM gradients, one column per ϑ

Both reductions complete **in-kernel**: z is accumulated across feature
tiles in a full-minibatch VMEM scratch (so callers never re-sum partials on
the host), g across batch tiles in a per-feature-tile scratch.  Inputs may
be bf16; all accumulation is f32 in VMEM.

Grid (nD, nB) — batch tiles minor-most (sequential) so the g accumulator
carries across batch tiles for a fixed feature tile; the z accumulator is a
full (B, M) scratch written through on every visit, so the last feature
pass (di == nD−1) leaves the completed sum in HBM (the grid is sequential:
last write wins).

Shapes that do not divide the tile are zero-padded inside the wrapper and
the outputs sliced back, so odd party widths (``PartyLayout.even`` with
d % q != 0) work without caller-side ceremony.

``mode`` selects which contraction is materialized:
  * "fused"    — both (the async hot loop: ϑ from the previous round is
                 applied while the next round's partials are produced);
  * "forward"  — z only (pre-aggregation, ϑ not yet known);
  * "backward" — g only (post-aggregation BUM application).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _vfl_kernel(*refs, lam: float, denom: int, block_b: int, fwd: bool,
                bwd: bool, has_w: bool):
    # Single-sided modes carry only their own operands/outputs (no HBM
    # traffic for a dead side); ref order follows the wrapper's specs.
    # ``has_w=False`` (backward with ``w=None``) additionally drops the
    # weight operand — the engine's multi-dominator BUM application only
    # needs XᵀΘ, so no dead (D, M) block is streamed into VMEM.
    if fwd and bwd:
        x_ref, w_ref, theta_ref, z_ref, g_ref, z_acc, g_acc = refs
    elif fwd:
        x_ref, w_ref, z_ref, z_acc = refs
    elif has_w:
        x_ref, w_ref, theta_ref, g_ref, g_acc = refs
    else:
        x_ref, theta_ref, g_ref, g_acc = refs
        w_ref = None
    di = pl.program_id(0)
    bi = pl.program_id(1)
    nb = pl.num_programs(1)

    x = x_ref[...].astype(jnp.float32)                    # (Bb, Db)
    w = None if w_ref is None else w_ref[...].astype(jnp.float32)  # (Db, M)

    if fwd:
        # forward partials for this (feature, batch) tile: rank-k MXU pass
        zt = jnp.dot(x, w, preferred_element_type=jnp.float32)   # (Bb, M)
        sl = pl.ds(bi * block_b, block_b)

        @pl.when(di == 0)
        def _z_init():
            z_acc[sl, :] = zt

        @pl.when(di > 0)
        def _z_accum():
            z_acc[sl, :] += zt

        # Written on every visit; the grid is sequential, so the final
        # feature pass (di == nD-1) is the last writer and the HBM block
        # holds the fully reduced z.  No out-of-kernel reduction remains.
        z_ref[...] = z_acc[sl, :]

    if bwd:
        @pl.when(bi == 0)
        def _g_init():
            g_acc[...] = jnp.zeros_like(g_acc)

        th = theta_ref[...].astype(jnp.float32)           # (Bb, M)
        # backward accumulate: XᵀΘ, f32 in VMEM
        g_acc[...] += jnp.dot(x.T, th, preferred_element_type=jnp.float32)

        @pl.when(bi == nb - 1)
        def _g_finalize():
            acc = g_acc[...] / denom
            if has_w:
                acc = acc + lam * w
            g_ref[...] = acc.astype(g_ref.dtype)


def vfl_grad(xb, w, theta, lam: float = 0.0, *, block_b: int = 128,
             block_d: int = 128, interpret: bool = True, mode: str = "fused",
             denom: int | None = None):
    """xb: (B, D); w: (D,) or (D, M); theta: (B,), (B, M) or None.

    Returns ``(z, g)`` with z = xb @ w fully reduced in-kernel (shape (B,)
    or (B, M)) and g = xbᵀθ/denom + λw (shape (D,) or (D, M)).  ``denom``
    defaults to B (the minibatch gradient 1/B scaling); SAGA's running
    average passes n.  Rank-1 inputs get rank-1 outputs.

    Single-sided modes return ``None`` for the inactive side and carry no
    HBM traffic for it; ``theta=None`` is allowed (and ϑ-free) in
    ``mode="forward"``, and ``w=None`` is allowed in ``mode="backward"``
    when ``lam == 0`` (pure XᵀΘ — the multi-dominator BUM application;
    the dead weight block is then never streamed into VMEM).
    """
    b, d = xb.shape
    assert mode in ("fused", "forward", "backward"), mode
    if w is None:
        assert mode == "backward", "w=None only valid in mode='backward'"
        assert lam == 0.0, "the λw term needs w; pass lam=0 with w=None"
        assert theta is not None
        squeeze = (theta.ndim == 1)
        w2 = None
        m = 1 if squeeze else theta.shape[1]
    else:
        squeeze = (w.ndim == 1)
        w2 = w[:, None] if w.ndim == 1 else w
        m = w2.shape[1]
    if theta is None:
        assert mode == "forward", "theta required outside mode='forward'"
        th2 = None
    else:
        th2 = theta[:, None] if theta.ndim == 1 else theta
        assert th2.shape == (b, m), (th2.shape, (b, m))
    denom = b if denom is None else int(denom)

    # Pad to tile multiples (sublane 8 for B, lane 128 for D) instead of
    # rejecting odd shapes; zero rows/cols contribute zero to both products.
    block_b = min(block_b, _round_up(b, 8))
    block_d = min(block_d, _round_up(d, 128))
    bp, dp = _round_up(b, block_b), _round_up(d, block_d)
    if bp != b or dp != d:
        xb = jnp.pad(xb, ((0, bp - b), (0, dp - d)))
        if w2 is not None:
            w2 = jnp.pad(w2, ((0, dp - d), (0, 0)))
        if th2 is not None:
            th2 = jnp.pad(th2, ((0, bp - b), (0, 0)))
    nb, nd = bp // block_b, dp // block_d

    fwd = mode in ("fused", "forward")
    bwd = mode in ("fused", "backward")
    has_w = w2 is not None
    kernel = functools.partial(_vfl_kernel, lam=lam, denom=denom,
                               block_b=block_b, fwd=fwd, bwd=bwd,
                               has_w=has_w)
    # Mode-specific specs: a single-sided call neither streams the unused
    # operand into VMEM nor DMAs a dead output back to HBM.
    th_spec = pl.BlockSpec((block_b, m), lambda di, bi: (bi, 0))
    z_spec = (pl.BlockSpec((block_b, m), lambda di, bi: (bi, 0)),
              jax.ShapeDtypeStruct((bp, m), jnp.float32),
              pltpu.VMEM((bp, m), jnp.float32))
    g_spec = (pl.BlockSpec((block_d, m), lambda di, bi: (di, 0)),
              jax.ShapeDtypeStruct((dp, m), jnp.float32),
              pltpu.VMEM((block_d, m), jnp.float32))
    sides = ([z_spec] if fwd else []) + ([g_spec] if bwd else [])
    w_spec = pl.BlockSpec((block_d, m), lambda di, bi: (di, 0))
    outs = pl.pallas_call(
        kernel,
        grid=(nd, nb),
        in_specs=[
            pl.BlockSpec((block_b, block_d), lambda di, bi: (bi, di)),
        ] + ([w_spec] if has_w else []) + ([th_spec] if bwd else []),
        out_specs=[s[0] for s in sides],
        out_shape=[s[1] for s in sides],
        scratch_shapes=[s[2] for s in sides],
        interpret=interpret,
    )(xb, *((w2,) if has_w else ()), *((th2,) if bwd else ()))
    z = outs[0][:b] if fwd else None
    g = outs[-1][:d] if bwd else None
    if squeeze:
        z = None if z is None else z[:, 0]
        g = None if g is None else g[:, 0]
    return z, g

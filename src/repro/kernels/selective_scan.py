"""Pallas TPU selective-scan (mamba-1 recurrence).

TPU-native adaptation (DESIGN §6): the GPU kernel's warp-parallel scan has
no TPU analogue; instead the sequence is processed in VMEM-resident chunks
with the (C_blk, N) state carried in VMEM scratch across sequential grid
steps (TPU grids iterate the minor-most dimension sequentially, which
Pallas guarantees for carried scratch).  Channels are blocked to fit VMEM
and map to the VPU lanes (128-multiples); the channel-block grid dimension
is parallel (the state is per-channel, no cross-channel coupling — the
same property that lets the party axis shard channels communication-free).

Layout: xa/dt (B, S, C); b/c_ssm (B, S, N); a_log (C, N); d_skip (C).
Grid (B, nC, nS) — nS minor-most (sequential), scratch h (C_blk, N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(xa_ref, dt_ref, b_ref, c_ref, alog_ref, dskip_ref, y_ref,
                 h_ref, *, chunk: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = -jnp.exp(alog_ref[...].astype(jnp.float32))        # (Cb, N)
    d_skip = dskip_ref[...].astype(jnp.float32)            # (Cb,)

    def step(t, h):
        xa_t = xa_ref[0, t].astype(jnp.float32)            # (Cb,)
        dt_t = dt_ref[0, t].astype(jnp.float32)            # (Cb,)
        b_t = b_ref[0, t].astype(jnp.float32)              # (N,)
        c_t = c_ref[0, t].astype(jnp.float32)              # (N,)
        da = jnp.exp(dt_t[:, None] * a)                    # (Cb, N)
        h = da * h + (dt_t * xa_t)[:, None] * b_t[None, :]
        y = jnp.sum(h * c_t[None, :], axis=1) + d_skip * xa_t
        y_ref[0, t] = y.astype(y_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


def selective_scan(xa, dt, b_ssm, c_ssm, a_log, d_skip, *,
                   chunk: int = 128, block_c: int = 512,
                   interpret: bool = True):
    """Returns (y (B,S,C), None).  Matches ``ref.selective_scan_ref`` (y)."""
    bsz, s, c = xa.shape
    n = a_log.shape[1]
    chunk = min(chunk, s)
    block_c = min(block_c, c)
    assert s % chunk == 0 and c % block_c == 0
    ns, nc = s // chunk, c // block_c

    kernel = functools.partial(_scan_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(bsz, nc, ns),
        in_specs=[
            pl.BlockSpec((1, chunk, block_c), lambda b, ci, si: (b, si, ci)),
            pl.BlockSpec((1, chunk, block_c), lambda b, ci, si: (b, si, ci)),
            pl.BlockSpec((1, chunk, n), lambda b, ci, si: (b, si, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, ci, si: (b, si, 0)),
            pl.BlockSpec((block_c, n), lambda b, ci, si: (ci, 0)),
            pl.BlockSpec((block_c,), lambda b, ci, si: (ci,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_c),
                               lambda b, ci, si: (b, si, ci)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, c), xa.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, n), jnp.float32)],
        interpret=interpret,
    )(xa, dt, b_ssm, c_ssm, a_log, d_skip)
    return y, None

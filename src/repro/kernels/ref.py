"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=None):
    """q: (B, H, Sq, dh); k/v: (B, Hkv, Skv, dh) -> (B, H, Sq, dh)."""
    b, h, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    rep = h // hkv
    kk = jnp.repeat(k, rep, axis=1)
    vv = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * dh ** -0.5,
                   kk.astype(jnp.float32))
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)
                      ).astype(q.dtype)


def selective_scan_ref(xa, dt, b_ssm, c_ssm, a_log, d_skip):
    """Sequential mamba-1 scan oracle.  See repro.models.ssm."""
    from repro.models.ssm import selective_scan_ref as _ref
    return _ref(xa, dt, b_ssm, c_ssm, a_log, d_skip)


def vfl_grad_ref(xb, w, theta, lam: float, denom=None):
    """Fused VFL forward partial + BUM backward (the paper's hot loop).

    Rank-k oracle: xb (B, D); w (D,) or (D, M); theta (B,) or (B, M).
    Returns (z = xb @ w, g = xbᵀθ/denom + λw) with the same rank as the
    inputs; ``denom`` defaults to B."""
    denom = xb.shape[0] if denom is None else denom
    z = xb.astype(jnp.float32) @ w.astype(jnp.float32)
    g = xb.astype(jnp.float32).T @ theta.astype(jnp.float32) \
        / denom + lam * w.astype(jnp.float32)
    return z, g

"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

Axis roles: see ``repro.sharding.api`` — "model" is the party axis (q=16
vertical-federated parties), "data" the intra-party collaborative level,
"pod" the inter-active-party-group level of BAPA.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh_for(devices: int, model_parallel: int,
                  pods: int = 1) -> Mesh:
    """Smaller meshes for tests/examples (same axis names, incl. 'pod')."""
    data = devices // (model_parallel * pods)
    devs = np.array(jax.devices()[:pods * data * model_parallel])
    devs = devs.reshape(pods, data, model_parallel)
    return Mesh(devs, ("pod", "data", "model"),
                axis_types=(AxisType.Auto,) * 3)


def batch_axes_for(mesh: Mesh):
    if "pod" in mesh.axis_names and mesh.shape.get("pod", 1) > 1:
        return ("pod", "data")
    return ("data",)

"""Production training launcher.

On real hardware this runs the same jitted ``train_step`` the dry-run
lowers, over the production mesh; on this CPU container it is exercised
with reduced configs + a small mesh (see examples/train_lm.py for the
runnable end-to-end driver).

Optimizers: ``adamw`` (default) or ``vfb2_sgd`` (bounded-staleness BAPA
emulation, --tau) — the paper's asynchronous update rule at framework
scale.
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import save_checkpoint
from repro.configs.base import ShapeConfig, get_arch
from repro.data.tokens import synthetic_token_batches
from repro.launch.mesh import batch_axes_for, make_mesh_for
from repro.models import model as model_lib
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.delayed import delayed_init, delayed_update
from repro.sharding.api import Runtime, use_runtime


def build_runtime(model_parallel: int, reduced: bool) -> Runtime:
    n = len(jax.devices())
    mp = min(model_parallel, n)
    mesh = make_mesh_for(n - n % mp, mp)
    kw = dict(attn_chunk=128, loss_chunk=64) if reduced else {}
    return Runtime(mesh=mesh, batch_axes=batch_axes_for(mesh), **kw)


def train(arch: str, steps: int, batch: int, seq: int, lr: float,
          optimizer: str = "adamw", tau: int = 4, reduced: bool = True,
          ckpt_dir: str | None = None, log_every: int = 10,
          model_parallel: int = 1):
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    rt = build_runtime(model_parallel, reduced)
    key = jax.random.PRNGKey(0)

    with use_runtime(rt):
        params = model_lib.init_params(cfg, key)
        if optimizer == "adamw":
            opt = adamw_init(params)
            upd = functools.partial(adamw_update, lr=lr)
        else:
            opt = delayed_init(params, tau)
            upd = functools.partial(delayed_update, lr=lr)

        @jax.jit
        def step_fn(params, opt, batch, key):
            loss, grads = jax.value_and_grad(
                lambda p: model_lib.train_loss(rt, cfg, p, batch, key)
            )(params)
            params, opt = upd(params, grads, opt)
            return loss, params, opt

        data = synthetic_token_batches(cfg.vocab, batch, seq, steps)
        losses = []
        t0 = time.time()
        for i, b in enumerate(data):
            if cfg.enc_dec:
                b["frames"] = jnp.zeros((batch, cfg.enc_seq, 2 * cfg.d_model),
                                        jnp.bfloat16)
            if cfg.arch_type == "vlm":
                b["patches"] = jnp.zeros((batch, cfg.n_patches, cfg.d_patch),
                                         jnp.bfloat16)
            key, sub = jax.random.split(key)
            loss, params, opt = step_fn(params, opt,
                                        jax.tree.map(jnp.asarray, b), sub)
            losses.append(float(loss))
            if i % log_every == 0:
                print(f"step {i:5d} loss {losses[-1]:.4f} "
                      f"({(time.time()-t0)/(i+1):.2f}s/step)")
        if ckpt_dir:
            save_checkpoint(ckpt_dir, {"params": params}, step=steps)
            print("checkpoint saved to", ckpt_dir)
        return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "vfb2_sgd"])
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--full", action="store_true",
                    help="full (production) config instead of reduced")
    ap.add_argument("--ckpt")
    ap.add_argument("--model-parallel", type=int, default=1)
    a = ap.parse_args()
    losses = train(a.arch, a.steps, a.batch, a.seq, a.lr, a.optimizer,
                   a.tau, reduced=not a.full, ckpt_dir=a.ckpt,
                   model_parallel=a.model_parallel)
    print(f"final loss: {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()

"""LM serving demo: batched prefill + greedy decode on the dormant
model stack (``repro.models``), run on small reduced configs.

This is a demo of the transformer stack only — the repo's real serving
subsystem is the secure federated inference path in ``repro.serve``
(request coalescing, passive-partial caches, masked aggregation at
inference; see ``docs/SERVING.md``).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_arch
from repro.configs.inputs import make_batch
from repro.launch.mesh import batch_axes_for, make_mesh_for
from repro.models import model as model_lib
from repro.sharding.api import Runtime, use_runtime


def serve(arch: str, batch: int = 4, prompt_len: int = 32,
          gen_tokens: int = 16, reduced: bool = True,
          model_parallel: int = 1, seed: int = 0):
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    n = len(jax.devices())
    mp = min(model_parallel, n)
    mesh = make_mesh_for(n - n % mp, mp)
    rt = Runtime(mesh=mesh, batch_axes=batch_axes_for(mesh),
                 attn_chunk=max(16, prompt_len // 2), loss_chunk=16)
    key = jax.random.PRNGKey(seed)
    max_len = prompt_len + gen_tokens

    with use_runtime(rt):
        params = model_lib.init_params(cfg, key)
        shape = ShapeConfig("serve", prompt_len, batch, "prefill")
        pre_batch = make_batch(cfg, shape, rt, seed=seed)

        prefill_fn = jax.jit(
            lambda p, b, k: model_lib.prefill(rt, cfg, p, b, k))
        decode_fn = jax.jit(
            lambda p, b, k: model_lib.decode_step(rt, cfg, p, b, k))

        t0 = time.time()
        tok, kv = prefill_fn(params, pre_batch, key)
        # re-home the prefill cache into a max_len cache
        cache = model_lib.init_cache(rt, cfg, batch, max_len)
        if kv is not None and isinstance(cache, dict) and "k" in cache:
            for name in kv:
                cache[name] = jax.lax.dynamic_update_slice_in_dim(
                    cache[name], kv[name].astype(cache[name].dtype),
                    0, axis=2) if cache[name].shape[2] >= kv[name].shape[2] \
                    else cache[name]
        t_pre = time.time() - t0
        out_tokens = [np.asarray(tok)]
        t1 = time.time()
        for i in range(gen_tokens - 1):
            key, sub = jax.random.split(key)
            step_batch = {"token": tok,
                          "pos": jnp.asarray(prompt_len + i, jnp.int32),
                          "cache": cache}
            tok, cache = decode_fn(params, step_batch, sub)
            out_tokens.append(np.asarray(tok))
        t_dec = time.time() - t1
        gen = np.stack(out_tokens, 1)
        print(f"prefill {batch}x{prompt_len} in {t_pre:.2f}s; "
              f"decode {gen_tokens-1} steps in {t_dec:.2f}s "
              f"({t_dec/max(gen_tokens-1,1)*1e3:.1f} ms/tok)")
        print("generated token ids (first 2 rows):\n", gen[:2])
        return gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    a = ap.parse_args()
    serve(a.arch, a.batch, a.prompt_len, a.gen_tokens,
          reduced=not a.full, model_parallel=a.model_parallel)


if __name__ == "__main__":
    main()

"""Roofline-term extraction from compiled XLA artifacts.

``collective_bytes`` is not in ``cost_analysis()`` — we parse the
post-SPMD-partitioning HLO text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Caveat (measured, see EXPERIMENTS §Roofline methodology): XLA's
HloCostAnalysis and the HLO text count a ``while`` (lax.scan) body ONCE,
not trip-count times.  The dry-run therefore lowers *unrolled* 1-layer and
2-layer variants and linearly extrapolates the marginal per-layer cost to
the full depth; the full scanned model is compiled separately to prove
memory fit and shardability.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")

# one matcher for "<lhs shapes> <kind>[-start|-done](": the lazy shapes
# group spans the whole LHS — including nested tuples like
# "(f32[8]{0}, (f32[4]{0}, pred[]))" that the old first-')'-truncating
# regex cut short — and the suffix group lets callers skip the -done half
# of async pairs (counting starts only, no double-counting).
_COLLECTIVE_RE = re.compile(
    r"=\s*(?P<shapes>.*?)\s*\b(?P<kind>" + "|".join(_COLLECTIVE_KINDS)
    + r")(?P<suffix>-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    """'f32[16,128]' -> byte size ('pred[]' -> 1); tuples handled by
    caller."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _line_output_bytes(line: str) -> int:
    """Bytes of the op's output (LHS shape), tuple-aware."""
    m = _COLLECTIVE_RE.search(line)
    if not m:
        return 0
    return sum(_shape_bytes(sm.group(0))
               for sm in _SHAPE_RE.finditer(m.group("shapes")))


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    bytes_by: Dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    count_by: Dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        # match op instructions only (e.g. "%x = f32[..] all-reduce(...)"),
        # including -start/-done async forms (count starts only)
        m = _COLLECTIVE_RE.search(line)
        if not m or m.group("suffix") == "-done":
            continue
        bytes_by[m.group("kind")] += _line_output_bytes(line)
        count_by[m.group("kind")] += 1
    return CollectiveStats(bytes_by, count_by)


# ---------------------------------------------------------------------------
# hardware model (TPU v5e)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device HLO bytes accessed
    coll_bytes: float            # per-device collective bytes
    model_flops: float           # analytic useful flops (global)
    n_chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        total_hlo = self.flops * self.n_chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    def to_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
        }


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N_active·D for training, 2·N_active·D for
    inference (D = tokens processed)."""
    n_active = active_param_count(cfg)
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token/seq


def param_count(cfg) -> float:
    """Total parameters (analytic, matches init_params)."""
    return _count(cfg, active_only=False)


def active_param_count(cfg) -> float:
    """Parameters touched per token (MoE: top-k experts only)."""
    return _count(cfg, active_only=True)


def _count(cfg, active_only: bool) -> float:
    d = cfg.d_model
    emb = cfg.padded_vocab * d
    total = emb + d  # embed + final norm (tied head)
    from repro.models.model import layer_kinds
    for kind in layer_kinds(cfg):
        total += d  # norm1
        if kind.startswith("attn"):
            dh, h, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv
            total += d * h * dh + 2 * d * hkv * dh + h * dh * d
        else:
            s = cfg.ssm
            ci = s.expand * d
            dt_rank = max(1, -(-d // 16))
            total += (d * 2 * ci + s.d_conv * ci + ci
                      + ci * (dt_rank + 2 * s.d_state)
                      + dt_rank * ci + ci + ci * s.d_state + ci + ci * d)
        if kind.endswith("mlp"):
            total += d + 3 * d * cfg.d_ff
        elif kind.endswith("moe"):
            e = cfg.moe.top_k if active_only else cfg.moe.n_experts
            total += d + cfg.d_model * cfg.moe.n_experts  # norm + router
            total += e * 3 * d * cfg.moe.d_expert
    if cfg.enc_dec:
        total += 2 * d * d  # enc_proj
        dh, h, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv
        per_enc = 2 * d + d * h * dh + 2 * d * hkv * dh + h * dh * d \
            + 3 * d * cfg.d_ff
        total += cfg.enc_layers * per_enc + d
        # decoder cross-attn
        total += cfg.n_layers * (d + d * h * dh + 2 * d * hkv * dh
                                 + h * dh * d)
    if cfg.arch_type == "vlm":
        total += cfg.d_patch * d
    return float(total)

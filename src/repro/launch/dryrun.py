import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Do not move them.

"""Multi-pod dry-run: prove every (arch × shape × mesh) lowers + compiles.

For each combination this script:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod);
  2. lowers + compiles train_step / prefill_step / serve_step against
     ShapeDtypeStruct inputs (no allocation);
  3. prints ``compiled.memory_analysis()`` (fit proof) and
     ``compiled.cost_analysis()`` (FLOPs/bytes for §Roofline);
  4. parses collective bytes from the post-SPMD HLO;
  5. writes a JSON record under results/dryrun/.

Roofline variants: ``--unroll N`` lowers an N-layer *unrolled* model
(see hlo_analysis module docstring for why).
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, get_arch, ARCH_IDS
from repro.configs.inputs import input_specs
from repro.launch import hlo_analysis
from repro.launch.mesh import batch_axes_for, make_production_mesh
from repro.models import model as model_lib
from repro.optim.adamw import adamw_init
from repro.sharding.api import Runtime, use_runtime


def batch_specs(cfg, shape, rt):
    """PartitionSpec tree matching input_specs structure."""
    bs = rt.bspec(shape.global_batch)
    if shape.mode in ("train", "prefill"):
        sp = {"tokens": P(bs, None)}
        if shape.mode == "train":
            sp["labels"] = P(bs, None)
        if cfg.enc_dec:
            sp["frames"] = P(bs, None, rt.model_axis)   # VFL feature split
        if cfg.arch_type == "vlm":
            sp["patches"] = P(bs, None, rt.model_axis)  # VFL feature split
        return sp
    return {"token": P(bs), "pos": P(),
            "cache": model_lib.cache_specs(rt, cfg, shape.global_batch)}


def shardings_of(rt, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(rt.mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _unrolled_cfg(cfg, n: int):
    """Shrink depth for the unrolled roofline variant (keeps per-layer
    structure: `n` layers, or `n` periods for hybrid archs)."""
    if cfg.period is not None:
        return dataclasses.replace(cfg, n_layers=n * len(cfg.period))
    return dataclasses.replace(cfg, n_layers=n,
                               enc_layers=min(cfg.enc_layers, n))


def build_step(cfg, shape, rt, mode, serve_weights="fsdp",
               cast_bf16: bool = False):
    key_s = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_s = jax.eval_shape(functools.partial(model_lib.init_params, cfg),
                              key_s)
    pspecs = model_lib.param_specs(cfg)
    if mode == "decode" and serve_weights == "replicated_bf16":
        pspecs = model_lib.serve_param_specs(cfg)
        params_s = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.bfloat16), params_s)
    bspecs = batch_specs(cfg, shape, rt)
    binputs = input_specs(cfg, shape, rt, abstract=True)

    if mode == "train":
        opt_s = jax.eval_shape(adamw_init, params_s)
        opt_specs = {"mu": pspecs, "nu": pspecs, "step": P()}

        def train_step(params, opt, batch, key):
            def loss_fn(p):
                if cast_bf16:
                    # cast the whole tree ONCE, before XLA's FSDP
                    # all-gathers: weight movement + grad reduction then
                    # happen in bf16 (half the collective bytes). §Perf.
                    p = jax.tree.map(
                        lambda a: a.astype(jnp.bfloat16)
                        if a.dtype == jnp.float32 else a, p)
                return model_lib.train_loss(rt, cfg, p, batch, key)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            from repro.optim.adamw import adamw_update
            params, opt = adamw_update(params, grads, opt)
            return loss, params, opt

        in_sh = (shardings_of(rt, pspecs), shardings_of(rt, opt_specs),
                 shardings_of(rt, bspecs), NamedSharding(rt.mesh, P()))
        out_sh = (NamedSharding(rt.mesh, P()), shardings_of(rt, pspecs),
                  shardings_of(rt, opt_specs))
        fn = jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0, 1))
        args = (params_s, opt_s, binputs, key_s)
        return fn, args

    if mode == "prefill":
        def prefill_step(params, batch, key):
            tok, _cache = model_lib.prefill(rt, cfg, params, batch, key)
            return tok
        bs = rt.bspec(shape.global_batch)
        in_sh = (shardings_of(rt, pspecs), shardings_of(rt, bspecs),
                 NamedSharding(rt.mesh, P()))
        out_sh = NamedSharding(rt.mesh, P(bs))
        fn = jax.jit(prefill_step, in_shardings=in_sh, out_shardings=out_sh)
        return fn, (params_s, binputs, key_s)

    # decode
    def serve_step(params, batch, key):
        return model_lib.decode_step(rt, cfg, params, batch, key)
    bs = rt.bspec(shape.global_batch)
    cache_sp = model_lib.cache_specs(rt, cfg, shape.global_batch)
    in_sh = (shardings_of(rt, pspecs), shardings_of(rt, bspecs),
             NamedSharding(rt.mesh, P()))
    out_sh = (NamedSharding(rt.mesh, P(bs)), shardings_of(rt, cache_sp))
    fn = jax.jit(serve_step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(1,))  # cache buffers update in place
    return fn, (params_s, binputs, key_s)


def run_one(arch_id: str, shape_name: str, multi_pod: bool,
            unroll: int | None, out_dir: str, cache_seq_axes=("model",),
            quiet: bool = False, secure_mode: str = "two_tree",
            moe_dispatch: str = "replicated",
            serve_weights: str = "fsdp",
            seq_parallel: bool = False,
            cast_bf16: bool = False) -> dict:
    cfg = get_arch(arch_id)
    shape = SHAPES[shape_name]
    if shape.name == "long_500k" and not cfg.supports_long:
        return {"arch": arch_id, "shape": shape_name,
                "status": "skipped (full attention; see DESIGN §Arch-applicability)"}
    if unroll is not None:
        cfg = _unrolled_cfg(cfg, unroll)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rt = Runtime(mesh=mesh, batch_axes=batch_axes_for(mesh),
                 unroll_layers=unroll, cache_seq_axes=tuple(cache_seq_axes),
                 secure_mode=secure_mode, moe_dispatch=moe_dispatch,
                 seq_parallel_norms=seq_parallel)
    rec = {"arch": arch_id, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "unroll": unroll, "cache_seq_axes": list(cache_seq_axes),
           "secure_mode": secure_mode, "moe_dispatch": moe_dispatch,
           "serve_weights": serve_weights}
    t0 = time.time()
    with use_runtime(rt):
        fn, args = build_step(cfg, shape, rt, shape.mode,
                              serve_weights=serve_weights,
                              cast_bf16=cast_bf16)
        lowered = fn.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        cost = compiled.cost_analysis()
        mem = compiled.memory_analysis()
        rec["flops_per_device"] = float(cost.get("flops", 0.0))
        rec["bytes_accessed_per_device"] = float(
            cost.get("bytes accessed", 0.0))
        rec["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
        }
        txt = compiled.as_text()
        coll = hlo_analysis.collective_stats(txt)
        rec["collectives"] = {"bytes_by_kind": coll.bytes_by_kind,
                              "count_by_kind": coll.count_by_kind,
                              "total_bytes": coll.total_bytes}
        rec["model_flops"] = hlo_analysis.model_flops(get_arch(arch_id),
                                                      shape)
        rec["param_count"] = hlo_analysis.param_count(get_arch(arch_id))
        rec["status"] = "ok"
        if not quiet:
            print(f"== {arch_id} × {shape_name} × {rec['mesh']}"
                  f"{' unroll=' + str(unroll) if unroll else ''} ==")
            print("memory_analysis:", rec["memory"])
            print("cost_analysis: flops/device={:.3e} bytes/device={:.3e}"
                  .format(rec["flops_per_device"],
                          rec["bytes_accessed_per_device"]))
            print("collectives:", coll.bytes_by_kind)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch_id}_{shape_name}_{rec['mesh']}" + \
            (f"_unroll{unroll}" if unroll else "") + \
            ("_seqdp" if tuple(cache_seq_axes) != ("model",) else "") + \
            ("_ring" if secure_mode == "ring_masks" else "") + \
            ("_a2a" if moe_dispatch == "alltoall" else "") + \
            ("_repw" if serve_weights == "replicated_bf16" else "") + \
            ("_sp" if seq_parallel else "") + \
            ("_bf16" if cast_bf16 else "")
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all", choices=list(SHAPES) + ["all"])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--unroll", type=int, default=None)
    ap.add_argument("--cache-seq-axes", default="model",
                    help="comma list, e.g. 'data,model' (perf hillclimb)")
    ap.add_argument("--secure-mode", default="two_tree",
                    choices=["two_tree", "ring_masks"])
    ap.add_argument("--moe-dispatch", default="replicated",
                    choices=["replicated", "alltoall"])
    ap.add_argument("--serve-weights", default="fsdp",
                    choices=["fsdp", "replicated_bf16"])
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--cast-bf16", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = run_one(arch, shape, mp, args.unroll, args.out,
                                  tuple(args.cache_seq_axes.split(",")),
                                  secure_mode=args.secure_mode,
                                  moe_dispatch=args.moe_dispatch,
                                  serve_weights=args.serve_weights,
                                  seq_parallel=args.seq_parallel,
                                  cast_bf16=args.cast_bf16)
                    if rec["status"].startswith("skipped"):
                        print(f"-- {arch} × {shape}: {rec['status']}")
                except Exception as e:  # pragma: no cover
                    traceback.print_exc()
                    failures.append((arch, shape, mp, str(e)))
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete: all combinations lowered + compiled.")


if __name__ == "__main__":
    main()

"""falcon-mamba-7b [arXiv:2410.05355] — attention-free mamba1."""
from repro.configs.base import ArchConfig, SSMSpec

CONFIG = ArchConfig(
    name="falcon-mamba-7b", arch_type="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv=0, d_ff=0, vocab=65024,
    ssm=SSMSpec(d_state=16, d_conv=4, expand=2), supports_long=True,
    citation="arXiv:2410.05355",
)

"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B] — 128 experts top-8."""
from repro.configs.base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", arch_type="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv=4, d_ff=768, vocab=151936,
    d_head=128, moe=MoESpec(n_experts=128, top_k=8, d_expert=768),
    rope_theta=1_000_000.0, citation="hf:Qwen/Qwen3-30B-A3B",
)

from repro.configs.base import (ArchConfig, MoESpec, SSMSpec, ShapeConfig,
                                SHAPES, ARCH_IDS, get_arch, all_archs)

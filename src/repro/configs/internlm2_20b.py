"""internlm2-20b [arXiv:2403.17297] — dense GQA."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b", arch_type="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv=8, d_ff=16384, vocab=92544,
    d_head=128, citation="arXiv:2403.17297",
)

"""granite-8b [arXiv:2405.04324] — llama-arch dense, code."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b", arch_type="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv=8, d_ff=14336, vocab=49152,
    d_head=128, citation="arXiv:2405.04324",
)

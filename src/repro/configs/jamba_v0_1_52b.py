"""jamba-v0.1-52b [arXiv:2403.19887] — Mamba+attention 1:7 interleave,
MoE 16 experts top-2 on every other layer; period of 8 layers with the
attention mixer at position 4 (Jamba paper Fig. 2)."""
from repro.configs.base import ArchConfig, MoESpec, SSMSpec

# Jamba block = {mamba|attention} mixer + {MLP|MoE} FFN; attention mixer at
# period position 4, MoE on every other layer (Jamba paper Fig. 2).
PERIOD = ("ssm_mlp", "ssm_moe", "ssm_mlp", "ssm_moe",
          "attn_mlp", "ssm_moe", "ssm_mlp", "ssm_moe")

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", arch_type="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336, vocab=65536,
    d_head=128, moe=MoESpec(n_experts=16, top_k=2, d_expert=14336),
    ssm=SSMSpec(d_state=16, d_conv=4, expand=2),
    period=PERIOD, supports_long=True, citation="arXiv:2403.19887",
)

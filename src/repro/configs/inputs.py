"""``input_specs()``: ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, no device allocation — the dry-run lowers
``train_step``/``serve_step`` against these.  ``make_batch`` materializes
small concrete batches for smoke tests / examples.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.model import init_cache
from repro.sharding.api import Runtime

I32 = jnp.int32
F32 = jnp.float32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def token_split(cfg: ArchConfig, seq_len: int) -> int:
    """Text-token count for VLM (patches occupy a prefix of the sequence)."""
    if cfg.arch_type == "vlm":
        return seq_len - cfg.n_patches
    return seq_len


def input_specs(cfg: ArchConfig, shape: ShapeConfig, rt: Runtime,
                abstract: bool = True) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    mk = _sds if abstract else (lambda sh, dt: jnp.zeros(sh, dt))
    s_text = token_split(cfg, s)

    if shape.mode in ("train", "prefill"):
        batch: Dict[str, Any] = {"tokens": mk((b, s_text), I32)}
        if shape.mode == "train":
            batch["labels"] = mk((b, s_text), I32)
        if cfg.enc_dec:
            batch["frames"] = mk((b, cfg.enc_seq, 2 * cfg.d_model),
                                 jnp.bfloat16)
        if cfg.arch_type == "vlm":
            batch["patches"] = mk((b, cfg.n_patches, cfg.d_patch),
                                  jnp.bfloat16)
        return batch

    # decode: one token against a cache of length seq_len
    cache = init_cache(rt, cfg, b, s, abstract=abstract)
    return {"token": mk((b,), I32),
            "pos": mk((), I32),
            "cache": cache}


def make_batch(cfg: ArchConfig, shape: ShapeConfig, rt: Runtime,
               seed: int = 0) -> Dict[str, Any]:
    """Concrete random batch (smoke tests; small shapes only)."""
    rng = np.random.default_rng(seed)
    b, s = shape.global_batch, shape.seq_len
    s_text = token_split(cfg, s)
    if shape.mode in ("train", "prefill"):
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (b, s_text)), I32)}
        if shape.mode == "train":
            batch["labels"] = jnp.asarray(
                rng.integers(0, cfg.vocab, (b, s_text)), I32)
        if cfg.enc_dec:
            batch["frames"] = jnp.asarray(
                rng.standard_normal((b, cfg.enc_seq, 2 * cfg.d_model)),
                jnp.bfloat16)
        if cfg.arch_type == "vlm":
            batch["patches"] = jnp.asarray(
                rng.standard_normal((b, cfg.n_patches, cfg.d_patch)),
                jnp.bfloat16)
        return batch
    cache = init_cache(rt, cfg, b, s, abstract=False)
    return {"token": jnp.asarray(rng.integers(0, cfg.vocab, (b,)), I32),
            "pos": jnp.asarray(s // 2, I32),
            "cache": cache}

"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs.base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", arch_type="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv=8, d_ff=512, vocab=49155,
    d_head=64, moe=MoESpec(n_experts=32, top_k=8, d_expert=512),
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

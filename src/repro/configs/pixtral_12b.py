"""pixtral-12b [hf:mistralai/Pixtral-12B-2409] — mistral-nemo decoder
backbone; pixtral-ViT vision encoder stubbed (input_specs supplies patch
embeddings, per assignment)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", arch_type="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv=8, d_ff=14336, vocab=131072,
    d_head=128, n_patches=1024, d_patch=1024, rope_theta=1_000_000.0,
    citation="hf:mistralai/Pixtral-12B-2409",
)

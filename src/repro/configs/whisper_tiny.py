"""whisper-tiny [arXiv:2212.04356] — enc-dec; conv/mel frontend stubbed
(input_specs supplies precomputed frame embeddings, per assignment)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", arch_type="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv=6, d_ff=1536, vocab=51865,
    d_head=64, enc_dec=True, enc_layers=4, enc_seq=1500,
    citation="arXiv:2212.04356",
)

"""Architecture + input-shape configuration system.

Every assigned architecture is a frozen ``ArchConfig`` (one module per arch
under ``repro.configs``), selectable via ``--arch <id>`` in the launchers.
``reduced()`` yields the CPU smoke-test variant (≤2 layers, d_model ≤ 512,
≤4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_expert: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                     # 0 for attn-free
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0                  # 0 => d_model // n_heads
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    # sliding-window pattern: window size + "every Nth layer is global"
    window: Optional[int] = None
    global_every: int = 0
    # hybrid (jamba): layer period description
    period: Optional[Tuple[str, ...]] = None   # e.g. ("ssm","ssm_moe",...)
    # enc-dec (whisper)
    enc_dec: bool = False
    enc_layers: int = 0
    enc_seq: int = 0                 # stub frontend frames
    # vlm (pixtral)
    n_patches: int = 0
    d_patch: int = 0                 # stub ViT embedding dim
    rope_theta: float = 10000.0
    citation: str = ""
    # long-context capability (sub-quadratic decode path exists)
    supports_long: bool = False

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab, 256)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant of the same family (per assignment rules)."""
        kw = dataclasses.asdict(self)
        if self.moe is not None:
            kw["moe"] = MoESpec(n_experts=min(4, self.moe.n_experts),
                                top_k=min(2, self.moe.top_k),
                                d_expert=64, capacity_factor=1.25)
        if self.ssm is not None:
            kw["ssm"] = SSMSpec(d_state=8, d_conv=4, expand=2)
        d_model = min(self.d_model, 128)
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = min(self.n_kv, max(1, n_heads // 2)) if self.n_kv else 0
        if self.period is not None:
            kw["period"] = ("ssm_mlp", "ssm_moe", "attn_mlp", "ssm_moe")
        kw.update(
            name=self.name + "-smoke",
            n_layers=2 if self.period is None else 4,
            d_model=d_model,
            n_heads=n_heads,
            n_kv=n_kv,
            d_head=(d_model // n_heads if n_heads else 0),
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            enc_layers=min(self.enc_layers, 2),
            enc_seq=min(self.enc_seq, 32),
            n_patches=min(self.n_patches, 8),
            d_patch=min(self.d_patch, 64),
            window=(min(self.window, 16) if self.window else None),
        )
        return ArchConfig(**{k: v for k, v in kw.items()})


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "granite_moe_1b_a400m",
    "internlm2_20b",
    "whisper_tiny",
    "granite_8b",
    "gemma3_4b",
    "qwen3_moe_30b_a3b",
    "jamba_v0_1_52b",
    "stablelm_1_6b",
    "pixtral_12b",
    "falcon_mamba_7b",
]


def get_arch(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def all_archs():
    return {a: get_arch(a) for a in ARCH_IDS}

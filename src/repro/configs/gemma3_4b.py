"""gemma3-4b [hf:google/gemma-3-1b-pt] — 5:1 local:global sliding window,
128k context => sub-quadratic long-context capable."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", arch_type="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv=4, d_ff=10240, vocab=262144,
    d_head=256, window=1024, global_every=6, supports_long=True,
    rope_theta=1_000_000.0, citation="hf:google/gemma-3-1b-pt",
)

"""stablelm-1.6b [hf:stabilityai/stablelm-2-1_6b] — dense MHA (kv=heads)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b", arch_type="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv=32, d_ff=5632, vocab=100352,
    d_head=64, citation="hf:stabilityai/stablelm-2-1_6b",
)

"""The paper's own workload: regularized (logistic) regression over
vertically partitioned features (problems 13/14/17/18)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paper-logreg", arch_type="linear",
    n_layers=0, d_model=0, n_heads=0, n_kv=0, d_ff=0, vocab=0,
    citation="this paper (AAAI'21, VFB^2)",
)
